"""Chunked online-softmax attention vs a naive reference, all variants."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.attention import build_kv_cache, chunked_attention


def naive(q, k, v, q_pos, kv_pos, scale, window=0, softcap=None):
    b, sq, h, d = q.shape
    _, skv, hkv, dv = v.shape
    g = h // hkv
    kf = np.repeat(np.asarray(k), g, axis=2)
    vf = np.repeat(np.asarray(v), g, axis=2)
    s = np.einsum("bqhd,bchd->bhqc", np.asarray(q, np.float64),
                  kf.astype(np.float64)) * scale
    if softcap:
        s = softcap * np.tanh(s / softcap)
    qp = np.asarray(q_pos)[:, None, :, None]
    kp = np.asarray(kv_pos)[:, None, None, :]
    ok = (kp <= qp) & (kp >= 0)
    if window > 0:
        ok &= qp - kp < window
    s = np.where(ok, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = np.where(ok, p, 0.0)
    p = p / np.maximum(p.sum(-1, keepdims=True), 1e-30)
    return np.einsum("bhqc,bchv->bqhv", p, vf.astype(np.float64))


@pytest.mark.parametrize("window", [0, 7])
@pytest.mark.parametrize("softcap", [None, 20.0])
@pytest.mark.parametrize("triangular", [False, True])
@pytest.mark.parametrize("hkv", [1, 2, 4])
def test_self_attention_variants(window, softcap, triangular, hkv):
    rng = np.random.default_rng(window * 31 + hkv)
    b, s, h, d = 2, 32, 4, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)
    out = chunked_attention(q, k, v, pos, pos, scale=d ** -0.5,
                            window=window, softcap=softcap, kv_chunk=8,
                            triangular=triangular)
    ref = naive(q, k, v, pos, pos, d ** -0.5, window, softcap)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-5)


def test_ring_cache_masks_by_absolute_position():
    """A rotated ring cache must attend identically to a fresh cache."""
    rng = np.random.default_rng(0)
    b, s, hkv, d, w = 1, 12, 1, 4, 8
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)
    cache = build_kv_cache(k, v, pos, cache_len=64, window=w)
    # the ring holds the LAST w positions, slot = pos % w
    kept = np.sort(np.asarray(cache["pos"][0]))
    assert np.array_equal(kept, np.arange(s - w, s))
    assert cache["k"].shape == (b, hkv, w, d)  # decode-optimized layout
    q = jnp.asarray(rng.standard_normal((b, 1, 2, d)), jnp.float32)
    qp = jnp.full((b, 1), s - 1, jnp.int32)
    out_ring = chunked_attention(q, cache["k"], cache["v"], qp, cache["pos"],
                                 scale=0.5, window=w, kv_chunk=8,
                                 kv_layout="bhsd")
    ref = naive(q, k, v, qp, pos, 0.5, window=w)
    np.testing.assert_allclose(np.asarray(out_ring), ref, rtol=2e-4, atol=2e-5)
