"""Docs integrity gate: the documentation must execute.

Three legs:

* **doctests** — the audited public compiler surface carries runnable
  examples in its docstrings; `pytest --doctest-modules` cannot import
  the `repro` namespace package, so the modules are run through
  `doctest.testmod` explicitly (and asserted non-empty, so silently
  dropping the examples fails loudly).
* **fenced blocks** — every ```` ```python ```` block in `README.md`
  and `docs/*.md` is extracted and executed.  Blocks within one file
  share a namespace, literate-style, so a guide can build on its own
  earlier snippets; illustrative non-runnable sketches use plain
  fences.
* **links** — every relative markdown link in those files must resolve
  to an existing file (web-relative links that escape the repo are
  skipped — they point at the forge, not the tree).
"""
import doctest
import importlib
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

DOCTEST_MODULES = [
    "repro.compiler.program",
    "repro.compiler.lowering",
    "repro.filters.bank",
]


@pytest.mark.parametrize("name", DOCTEST_MODULES)
def test_module_doctests(name):
    mod = importlib.import_module(name)
    res = doctest.testmod(
        mod,
        optionflags=doctest.NORMALIZE_WHITESPACE | doctest.ELLIPSIS,
        verbose=False,
    )
    assert res.attempted > 0, f"{name} lost its doctest examples"
    assert res.failed == 0, f"{name}: {res.failed} doctest(s) failed"


_BLOCK_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_fenced_python_blocks_execute(path):
    text = path.read_text()
    blocks = [
        (text[: m.start()].count("\n") + 2, m.group(1))
        for m in _BLOCK_RE.finditer(text)
    ]
    ns: dict = {}
    for line, code in blocks:
        try:
            exec(compile(code, f"{path.name}:{line}", "exec"), ns)
        except Exception as e:  # pragma: no cover - failure reporting
            raise AssertionError(
                f"{path.name}: fenced python block at line {line} "
                f"failed: {e!r}"
            ) from e


_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def test_no_dead_relative_links():
    dead = []
    for path in DOC_FILES:
        for target in _LINK_RE.findall(path.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (path.parent / rel).resolve()
            if not resolved.is_relative_to(ROOT):
                continue  # web-relative (e.g. ../../actions/...): not ours
            if not resolved.exists():
                dead.append(f"{path.name}: {target}")
    assert not dead, f"dead relative links: {dead}"
