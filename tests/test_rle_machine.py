"""The §2.4 RLE weight programs and the §4 dot-product machine testbench."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402
from hypothesis.extra.numpy import arrays  # noqa: E402

from repro.core import (code_count, code_count_batch, csd_digits,  # noqa: E402
                        decode_codes, encode_digits, encode_digits_batch,
                        po2_quantize)
from repro.core.machine import FirBlmacMachine, MachineSpec  # noqa: E402
from repro.filters import design_bank, fir_direct  # noqa: E402


@given(st.lists(st.integers(-32768, 32767), min_size=4, max_size=64))
@settings(max_examples=100, deadline=None)
def test_rle_roundtrip(ws):
    d = csd_digits(np.asarray(ws, np.int64), 16)
    st_ = encode_digits(d)
    assert np.array_equal(decode_codes(st_), d)
    assert st_.n_codes == np.count_nonzero(d) + 16


# arbitrary {-1,0,1} matrices, NOT just NAF outputs: adjacent pulses, dense
# layers, empty layers — anything the weight memory could be asked to hold.
# n_coeffs <= 64 keeps every zero-run inside the 6-bit ZRUN field.
_digit_matrices = arrays(
    np.int8,
    st.tuples(st.integers(1, 64), st.integers(1, 18)),
    elements=st.integers(-1, 1),
)


@given(_digit_matrices)
@settings(max_examples=100, deadline=None)
def test_rle_roundtrip_arbitrary_digits(d):
    stream = encode_digits(d)
    assert np.array_equal(decode_codes(stream), d)
    assert stream.n_codes == code_count(d)
    assert stream.n_pulses == np.count_nonzero(d)


@given(arrays(
    np.int8,
    st.tuples(st.integers(1, 6), st.integers(1, 32), st.integers(1, 8)),
    elements=st.integers(-1, 1),
))
@settings(max_examples=100, deadline=None)
def test_encode_digits_batch_matches_scalar(d):
    """The vectorized bank encoder is bit-identical to the scalar one on
    every row, for arbitrary digit matrices."""
    batch = encode_digits_batch(d)
    counts = code_count_batch(d)
    for b in range(d.shape[0]):
        ref = encode_digits(d[b])
        assert np.array_equal(batch.stream(b).codes, ref.codes)
        assert batch.n_codes[b] == ref.n_codes == counts[b]
        assert np.array_equal(decode_codes(batch.stream(b)), d[b])


def _machine_check(coeffs, seed=0, n_out=64, spec=None):
    spec = spec or MachineSpec()
    m = FirBlmacMachine(spec)
    stream = m.program(coeffs)
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, size=spec.taps - 1 + n_out)
    res = m.run(x)
    assert np.array_equal(res.outputs, fir_direct(x, coeffs))
    assert np.array_equal(res.cycles, np.full(n_out, stream.n_codes))
    return res


def test_machine_bit_exact_designed_filters():
    """The paper's testbench: ~18% of filters overflow the 256-code weight
    memory and are skipped; every filter that fits must be bit-exact."""
    bank = design_bank(127, [("lowpass", 0.23), ("highpass", 0.61),
                             ("bandpass", (0.2, 0.5)), ("bandstop", (0.3, 0.8))])
    verified = 0
    for h in bank:
        q, _ = po2_quantize(h, 16)
        try:
            _machine_check(q)
            verified += 1
        except ValueError as e:
            assert "weight memory" in str(e)
    assert verified >= 2


def test_machine_extreme_coefficients():
    w = np.zeros(127, np.int64)
    w[63] = 32767  # centre tap at int16 max
    _machine_check(w)
    w2 = np.zeros(127, np.int64)
    w2[0] = w2[126] = -32768
    w2[63] = 1
    _machine_check(w2)


def test_weight_memory_overflow_raises():
    rng = np.random.default_rng(3)
    half = rng.integers(-32768, 32768, 64)
    w = np.concatenate([half[:63], half[63:64], half[:63][::-1]])
    m = FirBlmacMachine(MachineSpec(weight_mem_codes=64))
    with pytest.raises(ValueError, match="weight memory"):
        m.program(w)


def test_fused_last_add_saves_cycles():
    bank = design_bank(127, [("lowpass", 0.3)])
    q, _ = po2_quantize(bank[0], 16)
    base = _machine_check(q)
    fused = FirBlmacMachine(MachineSpec(fused_last_add=True))
    fused.program(q)
    rng = np.random.default_rng(0)
    x = rng.integers(-128, 128, size=127 - 1 + 64)
    res = fused.run(x)
    assert np.array_equal(res.outputs, base.outputs)
    assert res.cycles[0] < base.cycles[0]  # §4: "reduce ... by 16"


def test_type2_rejected():
    m = FirBlmacMachine()
    with pytest.raises(ValueError):
        m.program(np.arange(127))  # not symmetric
