"""Per-architecture smoke tests (deliverable f): reduced config, one
forward/train step on CPU, shapes + finiteness; full-config param counts
checked abstractly (no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, get_config
from repro.nn import (ShardCtx, count_params, forward, init_params, loss_fn,
                      model_decls)

ARCHS = sorted(all_configs())

# published sizes (±10%): internvl2 counts only the 70B LLM backbone
PUBLISHED_B = {
    "deepseek-coder-33b": 33.3, "deepseek-v3-671b": 671.0,
    "gemma2-27b": 27.2, "internvl2-76b": 69.5, "mamba2-370m": 0.37,
    "mixtral-8x22b": 140.6, "musicgen-large": 2.4, "qwen2.5-3b": 3.1,
    "recurrentgemma-2b": 2.9, "starcoder2-3b": 3.2,
}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    cfg = get_config(arch)
    n = count_params(model_decls(cfg)) / 1e9
    assert abs(n - PUBLISHED_B[arch]) / PUBLISHED_B[arch] < 0.10, n


def _batch(cfg, rng, B, S):
    base = {
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.input_kind == "embeds":
        base["embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    else:
        base["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return base


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(hash(arch) % 2**31)
    params = init_params(model_decls(cfg), jax.random.key(0))
    B, S = 2, 32
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    ctx = ShardCtx(positions=pos, compute_dtype=jnp.float32)
    batch = _batch(cfg, rng, B, S)
    logits, aux, _ = jax.jit(lambda p, b: forward(p, b, cfg, ctx))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p, b: loss_fn(p, b, cfg, ctx), has_aux=True)
    )(params, batch)
    assert bool(jnp.isfinite(loss))
    gn = sum(jnp.sum(g.astype(jnp.float32) ** 2)
             for g in jax.tree_util.tree_leaves(grads))
    assert bool(jnp.isfinite(gn))


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-370m",
                                  "recurrentgemma-2b", "mixtral-8x22b",
                                  "deepseek-v3-671b", "gemma2-27b"])
def test_smoke_train_step_improves(arch):
    from repro.data import DataConfig, TokenPipeline
    from repro.training import (OptHParams, TrainHParams, make_train_step,
                                train_state_init)
    from repro.nn import init_params, model_decls

    cfg = get_config(arch).reduced(vocab_size=128)
    pipe = TokenPipeline(DataConfig(128, 8, 32, seed=0))
    hp = TrainHParams(opt=OptHParams(learning_rate=3e-3, warmup_steps=2,
                                     total_steps=20))
    step = jax.jit(make_train_step(cfg, hp))
    state = train_state_init(init_params(model_decls(cfg), jax.random.key(1)), cfg)
    losses = []
    for i in range(12):
        b = {k: jnp.asarray(v) for k, v in pipe.global_batch_at(i).items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses
