"""Sparsity-schedule coverage: layer-skip, occupancy grouping, fast path.

Oracle-vs-kernel equality on the banks a schedule can get wrong (all-zero
rows, single pulses at the extreme layers, mixed occupancy in hostile
order), schedule-compilation unit tests, the autotuned dispatch, and the
pack-time int32 bound.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (assert_int32_bound, layer_occupancy,
                        layer_pulse_counts, occupancy_signatures,
                        po2_quantize_batch)
from repro.core.csd import csd_digits
from repro.filters import FilterBankEngine, design_bank, fir_bit_layers_batch
from repro.kernels import (autotune_bank_dispatch, pack_bank_trits,
                           plan_bank_schedule, superlayer_schedule)
from repro.kernels.blmac_fir import blmac_fir_bank  # packed-operand entry

from differential import adversarial_bank, four_way_check


def _sym(half_rows) -> np.ndarray:
    return np.stack(
        [np.concatenate([h, h[:-1][::-1]]) for h in np.atleast_2d(half_rows)]
    )


# ---------------------------------------------------------------------------
# schedule compilation (pure planning, no kernels)
# ---------------------------------------------------------------------------

def test_superlayer_schedule_empty_and_single():
    assert superlayer_schedule((), 4) == ((), 0, ())
    sched, tail, sel = superlayer_schedule((7,), 4)
    assert sched == ((0, ((0, 0),)),) and tail == 7 and sel == (7,)


def test_superlayer_schedule_merge_and_gaps():
    # layers {16, 4, 3, 1, 0}, merge=2: {16}, {4,3}, {1,0}
    sched, tail, sel = superlayer_schedule((0, 1, 3, 4, 16), 2)
    assert sel == (16, 4, 3, 1, 0)
    assert sched == (
        (0, ((0, 0),)),          # layer 16
        (13, ((1, 1), (2, 0))),  # acc << (16-3), then 2·d4 + d3
        (3, ((3, 1), (4, 0))),   # acc << (3-0), then 2·d1 + d0
    )
    assert tail == 0


def test_superlayer_schedule_merge1_is_pure_bit_layers():
    sched, tail, sel = superlayer_schedule((0, 2, 5), 1)
    assert all(len(parts) == 1 and parts[0][1] == 0 for _, parts in sched)
    assert [s for s, _ in sched] == [0, 3, 2] and tail == 0


def test_schedule_decodes_to_weights():
    """Replaying a schedule over the digit layers reproduces the weights —
    the same recursion the kernel runs, on numpy."""
    rng = np.random.default_rng(3)
    w = rng.integers(-(1 << 15), 1 << 15, 9)
    digits = csd_digits(w)  # (M, L)
    occ = np.nonzero(layer_occupancy(digits[None]).any(axis=0))[0]
    for merge in (1, 3, 8):
        sched, tail, sel = superlayer_schedule(tuple(occ), merge)
        acc = np.zeros_like(w)
        for shift_in, parts in sched:
            acc <<= shift_in
            for sel_idx, rel in parts:
                acc += digits[:, sel[sel_idx]].astype(np.int64) << rel
        assert np.array_equal(acc << tail, w), merge


def test_occupancy_helpers():
    d = np.zeros((2, 3, 5), np.int8)
    d[0, 1, 2] = 1
    d[1, 0, 0] = -1
    d[1, 2, 4] = 1
    occ = layer_occupancy(d)
    assert occ.tolist() == [
        [False, False, True, False, False],
        [True, False, False, False, True],
    ]
    assert layer_pulse_counts(d)[1].tolist() == [1, 0, 0, 0, 1]
    sigs = occupancy_signatures(occ)
    assert sigs.tolist() == [0b00100, 0b10001]


# ---------------------------------------------------------------------------
# kernel equality on adversarial occupancy
# ---------------------------------------------------------------------------

def test_all_zero_bank_runs_no_kernel():
    q = np.zeros((5, 15), np.int64)
    packed = pack_bank_trits(q)
    plan = plan_bank_schedule(packed, bank_tile=4)
    assert all(not g.sel_layers for g in plan.groups)
    assert plan.n_superlayers == 0
    x = np.arange(200)
    y = blmac_fir_bank(jnp.asarray(x), packed, 15, tile=128, fast_path=False)
    assert y.shape == (5, 200 - 15 + 1)
    assert not np.asarray(y).any()


def test_single_pulse_filters_every_layer():
    """One filter per bit layer, each a lone centre-tap pulse: the
    schedule must place every pulse at its exact weight."""
    half = 7
    rows = []
    for layer in range(15):
        h = np.zeros(half + 1, np.int64)
        h[half] = 1 << layer
        rows.append(h)
    q = _sym(rows)
    rng = np.random.default_rng(5)
    x = rng.integers(-128, 128, (1, 300))
    for bank_tile in (1, 4, 16):
        y = blmac_fir_bank(
            jnp.asarray(x), pack_bank_trits(q), q.shape[1],
            tile=128, bank_tile=bank_tile, fast_path=False,
        )
        assert np.array_equal(
            np.asarray(y, np.int64), fir_bit_layers_batch(x, q)
        ), bank_tile


@pytest.mark.parametrize("merge", [1, 4, 8])
def test_mixed_occupancy_order_restored(merge):
    """Hostile interleaving of dense / sparse / empty rows: grouping must
    sort internally and hand back rows in the caller's order."""
    q = adversarial_bank(taps=31)
    packed = pack_bank_trits(q)
    plan = plan_bank_schedule(packed, bank_tile=2, merge=merge)
    assert not np.array_equal(plan.perm, np.arange(len(q)))  # sort happened
    rng = np.random.default_rng(7)
    x = rng.integers(-128, 128, (2, 500))
    y = blmac_fir_bank(
        jnp.asarray(x), packed, 31, tile=128, bank_tile=2, merge=merge,
        fast_path=False,
    )
    assert np.array_equal(np.asarray(y, np.int64), fir_bit_layers_batch(x, q))


def test_grouped_tiles_skip_layers():
    """A tile of low-layer-only filters must compile fewer superlayers
    than the dense tiles — that is the whole point of grouping."""
    rng = np.random.default_rng(11)
    dense = rng.integers(-(1 << 15), 1 << 15, (4, 8))
    sparse = rng.integers(-3, 4, (4, 8))
    q = _sym(np.concatenate([dense, sparse]))[np.array([0, 4, 1, 5, 2, 6, 3, 7])]
    plan = plan_bank_schedule(pack_bank_trits(q), bank_tile=4, merge=1)
    n_super = sorted(len(g.schedule) for g in plan.groups)
    assert len(plan.groups) == 2
    assert n_super[0] <= 3  # sparse tile: ±3 coeffs → ≤3 populated layers
    assert n_super[1] >= 15  # dense 16-bit tile
    x = rng.integers(-128, 128, (1, 400))
    y = blmac_fir_bank(jnp.asarray(x), pack_bank_trits(q), 15, tile=128,
                       bank_tile=4, merge=1, fast_path=False)
    assert np.array_equal(np.asarray(y, np.int64), fir_bit_layers_batch(x, q))


def test_fast_path_matches_bank_path():
    q = _sym(np.random.default_rng(13).integers(-(1 << 15), 1 << 15, (1, 16)))
    packed = pack_bank_trits(q)
    x = np.random.default_rng(14).integers(-128, 128, 700)
    fast = blmac_fir_bank(jnp.asarray(x), packed, 31, tile=128)
    slow = blmac_fir_bank(jnp.asarray(x), packed, 31, tile=128, fast_path=False)
    assert np.array_equal(np.asarray(fast), np.asarray(slow))
    assert np.array_equal(
        np.asarray(fast, np.int64), fir_bit_layers_batch(x, q)[:, 0, :]
    )


# ---------------------------------------------------------------------------
# autotuner + engine dispatch
# ---------------------------------------------------------------------------

def test_autotuner_scales_with_bank_width():
    def bank(n, taps=63):
        cuts = 0.05 + 0.9 * (np.arange(n) + 0.5) / n
        q, _ = po2_quantize_batch(
            design_bank(taps, [("lowpass", float(c)) for c in cuts]), 16
        )
        return pack_bank_trits(q)

    plan1, sched1 = autotune_bank_dispatch(bank(1), 63)
    assert plan1.mode == "specialized" and sched1 is None
    plan256, sched256 = autotune_bank_dispatch(bank(256), 63, chunk_hint=8192)
    assert plan256.mode == "scheduled"
    assert plan256.merge > 1  # superlayer fusion beats per-bit-layer matmuls
    assert sched256 is not None and sched256.tile_size == plan256.bank_tile
    # repeat dispatch is an LRU hit returning the identical plan object
    again, _ = autotune_bank_dispatch(bank(256), 63, chunk_hint=8192)
    assert again is plan256


def test_engine_scheduled_streaming_on_adversarial_bank():
    q = adversarial_bank(taps=15)
    rng = np.random.default_rng(17)
    x = rng.integers(-128, 128, (1, 900))
    eng = FilterBankEngine(q, channels=1, tile=128, mode="packed")
    cuts = [0, 50, 51, 400, 900]
    y = np.concatenate(
        [eng.push(x[:, a:b]) for a, b in zip(cuts, cuts[1:])], axis=2
    )
    assert np.array_equal(y, fir_bit_layers_batch(x, q))


# ---------------------------------------------------------------------------
# four-way differential through the scheduled path
# ---------------------------------------------------------------------------

def test_four_way_adversarial_bank():
    rep = four_way_check(adversarial_bank(taps=31), n_out=24, tile=128)
    assert rep.n_filters == 7


def test_four_way_sweep_sampled_bank():
    from differential import sampled_sweep_bank

    rep = four_way_check(
        sampled_sweep_bank(taps=127, n_filters=6), n_out=24, tile=128
    )
    assert rep.n_filters == 6


# ---------------------------------------------------------------------------
# pack-time int32 bound (the single overflow check every path shares)
# ---------------------------------------------------------------------------

def test_int32_bound_asserted_once_at_pack_time():
    ok = _sym(np.full((1, 128), (1 << 15) - 1, np.int64))  # 255 taps, max coeffs
    assert ok.shape[1] == 255
    bound = assert_int32_bound(ok, sample_bits=8)
    assert bound < 1 << 31
    pack_bank_trits(ok)  # must not raise: the paper's operating point fits
    with pytest.raises(OverflowError):
        assert_int32_bound(ok, sample_bits=16)  # 16-bit samples do NOT fit
    with pytest.raises(OverflowError):
        pack_bank_trits(ok, sample_bits=16)
