"""End-to-end behaviour of the paper's system + the LM framework around it.

The paper's pipeline: design filter → quantize int16 → CSD/RLE program →
BLMAC applies it with ~B_N additions, bit-exactly — validated from float
design all the way to the Pallas kernel.  The framework: train → checkpoint
→ serve, with the BLMAC quantizer in the serving path.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (fir_blmac_additions, po2_quantize,
                        classical_equivalent_adds)
from repro.core.machine import FirBlmacMachine, MachineSpec
from repro.filters import design_bank, fir_direct
from repro.kernels import blmac_fir


def test_paper_pipeline_end_to_end():
    """float design → int16 → BLMAC (machine AND kernel) → bit-exact,
    at the paper's advertised cost."""
    h = design_bank(127, [("bandpass", (0.15, 0.45))])[0]
    q, k = po2_quantize(h, 16)
    adds = fir_blmac_additions(q)
    # Fig. 3 neighbourhood at N=127, and the paper's headline win
    assert 150 < adds < 400
    assert classical_equivalent_adds(127) / adds > 2.5

    rng = np.random.default_rng(0)
    x = rng.integers(-128, 128, 127 + 255)
    expect = fir_direct(x, q)

    machine = FirBlmacMachine(MachineSpec())
    machine.program(q)
    res = machine.run(x)
    assert np.array_equal(res.outputs, expect)
    # machine cycles == RLE code count == pulses + 16 EORs
    assert res.mean_cycles == res.stream.n_pulses + 16
    # adds (pulses over half coeffs) consistent with the cost model
    assert res.stream.n_pulses == adds - 127 // 2

    y = blmac_fir(jnp.asarray(x, jnp.int32), q)
    assert np.array_equal(np.asarray(y), expect)


def test_quantization_roundtrip_error_bounded():
    bank = design_bank(55, [("lowpass", 0.3)])
    q, k = po2_quantize(bank[0], 16)
    rec = q.astype(np.float64) / 2.0 ** k
    assert np.abs(rec - bank[0]).max() <= 2.0 ** -(k + 1)


def test_train_checkpoint_serve_cycle(tmp_path):
    from repro.configs import get_config
    from repro.checkpoint import restore_checkpoint
    from repro.data import DataConfig, TokenPipeline
    from repro.distributed.fault import TrainLoop
    from repro.serving import ServeEngine
    from repro.training import OptHParams, TrainHParams

    cfg = get_config("qwen2.5-3b").reduced(n_layers=2, vocab_size=128,
                                           d_model=64, d_ff=128)
    pipe = TokenPipeline(DataConfig(128, 8, 32, seed=7))
    # 120 steps @ 1e-2 reliably memorizes the affine markov map (agree=1.0
    # in ~10s); the seed's 30 steps @ 3e-3 left the model at chance level
    hp = TrainHParams(opt=OptHParams(learning_rate=1e-2, warmup_steps=3,
                                     total_steps=120))
    loop = TrainLoop(cfg, hp, pipe, str(tmp_path), ckpt_every=40)
    hist = loop.run(120)
    assert hist[-1]["loss"] < hist[0]["loss"]

    state, step = restore_checkpoint(str(tmp_path), loop.state)
    assert step == 120
    eng = ServeEngine(cfg, state["params"], cache_len=64)
    out = eng.generate(np.zeros((2, 8), np.int32), max_new_tokens=6)
    assert out.shape == (2, 6)
    # markov data: generated continuations should follow the affine
    # next-token map much more often than chance (1/128)
    nxt = (np.asarray(out[:, :-1]).astype(np.int64) * pipe._a + pipe._c) % 128
    agree = (np.asarray(out[:, 1:]) == nxt).mean()
    assert agree > 0.5, agree
