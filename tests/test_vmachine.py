"""The vectorized BLMAC machine simulator, via the four-way differential
harness (`tests/differential.py`): oracle ⇄ Pallas bank kernel ⇄ scalar
`FirBlmacMachine` ⇄ `FirBlmacVMachine`, plus cycle-count and
weight-memory-overflow parity."""
import numpy as np
import pytest

from repro.core import (FirBlmacMachine, FirBlmacVMachine, MachineSpec,
                        csd_digits, encode_digits, encode_digits_batch,
                        machine_cycles, machine_cycles_batch, simulate_bank)
from tests.differential import (four_way_check, random_type1_bank,
                                sampled_sweep_bank)


@pytest.mark.parametrize("taps,n_filters", [(15, 6), (31, 5), (63, 4)])
def test_four_way_random_banks(taps, n_filters):
    # sparse banks so most programs fit the weight memory
    q = random_type1_bank(n_filters, taps, seed=taps, density=0.6)
    rep = four_way_check(q, seed=taps)
    assert rep.n_filters == n_filters
    assert rep.scalar_checked + rep.scalar_rejected > 0


def test_four_way_sweep_filters_127_taps():
    """Real filters from the paper's design sweep at the paper's tap count,
    including some that overflow the 256-entry weight memory."""
    q = sampled_sweep_bank(taps=127, n_div=10, n_filters=8, seed=1)
    rep = four_way_check(q, scalar_samples=3, seed=2)
    assert rep.n_out == 48


def test_four_way_dense_random_bank_overflows():
    """Dense random 16-bit coefficients need ~370 codes — every filter
    must be rejected by BOTH machines, outputs still exact."""
    q = random_type1_bank(4, 127, seed=9)
    rep = four_way_check(q, scalar_samples=2, seed=3)
    assert not rep.fits.any()
    assert rep.scalar_rejected == 4


def test_four_way_fused_last_add_spec():
    q = random_type1_bank(4, 31, seed=5, density=0.5)
    spec = MachineSpec(taps=31, fused_last_add=True)
    four_way_check(q, spec=spec, seed=6)


def test_four_way_start_overhead_spec():
    q = random_type1_bank(3, 15, seed=7, density=0.5)
    spec = MachineSpec(taps=15, start_overhead=2)
    rep = four_way_check(q, spec=spec, seed=8)
    base = four_way_check(q, spec=MachineSpec(taps=15), seed=8)
    assert rep.mean_cycles == base.mean_cycles + 2


def test_vmachine_single_filter_row_equals_scalar_full_run():
    """Every output position (not a sample) of a long run, one filter."""
    q = random_type1_bank(1, 31, seed=11, density=0.4)
    spec = MachineSpec(taps=31)
    rng = np.random.default_rng(12)
    x = rng.integers(-128, 128, 31 - 1 + 300)
    vres = simulate_bank(q, x, spec)
    m = FirBlmacMachine(spec)
    m.program(q[0])
    sres = m.run(x)
    assert np.array_equal(vres.outputs[0], sres.outputs)
    assert np.array_equal(vres.cycles[0], sres.cycles)


def test_vmachine_fused_variant_saves_16_cycles_on_full_program():
    """§4: fusing the last add with the shift saves one cycle per
    non-empty bit layer — exactly 16 for a fully-populated program."""
    q = sampled_sweep_bank(taps=127, n_div=10, n_filters=6, seed=13)
    base = machine_cycles_batch(q)
    fused = machine_cycles_batch(q, fused_last_add=True)
    nonempty = np.count_nonzero(
        csd_digits(q[:, :64], n_digits=16).any(axis=1), axis=-1
    )
    assert np.array_equal(base - fused, nonempty)
    assert (base - fused).max() == 16  # real 16-bit filters fill all layers


def test_machine_cycles_batch_matches_scalar():
    q = random_type1_bank(6, 15, seed=14, density=0.7)
    batch = machine_cycles_batch(q, n_layers=16, overhead=1)
    for b in range(6):
        assert batch[b] == machine_cycles(q[b], n_layers=16, overhead=1)


def test_encode_digits_batch_matches_scalar_rows():
    q = random_type1_bank(5, 31, seed=15, density=0.5)
    d = csd_digits(q[:, :16], n_digits=16)
    batch = encode_digits_batch(d)
    for b in range(5):
        s = encode_digits(d[b])
        assert np.array_equal(batch.stream(b).codes, s.codes)
        assert batch.n_codes[b] == s.n_codes
        assert batch.n_pulses[b] == s.n_pulses
        assert batch.fits()[b] == s.fits()
    assert len(batch) == 5


def test_encode_digits_batch_zrun_overflow_raises():
    d = np.zeros((2, 100, 3), np.int8)
    d[1, 70, 1] = 1  # 70 leading zeros > 63
    with pytest.raises(ValueError, match="ZRUN"):
        encode_digits_batch(d)


def test_vmachine_zrun_overflow_sets_fit_mask():
    """A filter whose digits need a >63 zero-run is unprogrammable — the
    scalar encoder raises; the vectorized mask must say False."""
    taps = 255  # n_half = 128 > 64: runs can overflow the 6-bit field
    q = np.zeros((2, taps), np.int64)
    q[0, 127] = 3  # centre tap only: runs of 127 zeros… nope: pulse at 127
    q[1, 0] = q[1, -1] = 1  # pulse at j=0 then 127 zeros: fine (no pulse after)
    # filter 0: centre pulse at j=127 → zero-run of 127 before it
    spec = MachineSpec(taps=taps)
    vm = FirBlmacVMachine(spec)
    fits = vm.program_bank(q)
    assert not fits[0] and fits[1]
    m = FirBlmacMachine(spec)
    with pytest.raises(ValueError, match="ZRUN"):
        m.program(q[0])
    m.program(q[1])


def test_vmachine_validation_errors():
    vm = FirBlmacVMachine(MachineSpec(taps=15))
    with pytest.raises(RuntimeError, match="not programmed"):
        vm.run(np.zeros(20))
    with pytest.raises(ValueError, match="symmetric"):
        vm.program_bank(np.arange(15))
    with pytest.raises(ValueError, match="expected"):
        vm.program_bank(np.zeros((2, 11), np.int64))
    big = np.full((1, 15), 1 << 20, np.int64)
    with pytest.raises(ValueError, match="exceed"):
        vm.program_bank(big)
    vm.program_bank(random_type1_bank(2, 15, seed=1, density=0.5))
    with pytest.raises(ValueError, match="samples exceed"):
        vm.run(np.full(20, 1000))
    with pytest.raises(ValueError, match="at least"):
        vm.run(np.zeros(10))
    with pytest.raises(ValueError, match="1-D"):
        vm.run(np.zeros((2, 20)))


def test_vmachine_default_spec_is_fresh_per_instance():
    """The MachineSpec-default footgun: two machines must not share one
    import-time default instance."""
    a, b = FirBlmacMachine(), FirBlmacMachine()
    assert a.spec is not b.spec
    va, vb = FirBlmacVMachine(), FirBlmacVMachine()
    assert va.spec is not vb.spec


def test_vmachine_programs_roundtrip():
    q = random_type1_bank(3, 31, seed=21, density=0.5)
    vm = FirBlmacVMachine(MachineSpec(taps=31))
    vm.program_bank(q)
    batch = vm.programs()
    d = csd_digits(q[:, :16], n_digits=16)
    for b in range(3):
        assert np.array_equal(batch.stream(b).codes, encode_digits(d[b]).codes)
