"""Kill-and-resume demo: crash-safe multi-tenant serving.

Launches a `BankSessionServer` with a write-ahead journal, streams a few
chunks for every tenant, then SIGKILLs the serving process mid-flight —
with chunks still queued and outputs still undelivered.  A fresh process
calls `BankSessionServer.recover(journal)` and keeps serving; at the end
every tenant's concatenated stream is bit-exact against an uninterrupted
numpy-oracle run.

    PYTHONPATH=src python examples/session_recovery.py
"""
import argparse
import os
import signal
import subprocess
import sys
import tempfile

import numpy as np

ap = argparse.ArgumentParser()
ap.add_argument("--sessions", type=int, default=8)
ap.add_argument("--taps", type=int, default=31)
ap.add_argument("--chunk", type=int, default=256)
args = ap.parse_args()

workdir = tempfile.mkdtemp(prefix="blmac_recovery_")
journal = os.path.join(workdir, "wal")

# phase 1 runs in a subprocess so this script can SIGKILL it the way a
# real crash would — no atexit, no finally blocks, no flushes.
VICTIM = f"""
import os, signal
import numpy as np
from repro.compiler import compile_bank
from repro.filters import spread_lowpass_qbank
from repro.serving import BankSessionServer

prog = compile_bank(spread_lowpass_qbank(64, {args.taps}))
srv = BankSessionServer(prog, n_slots=4, auto_step=False,
                        journal={journal!r}, snapshot_every=2)
rng = np.random.default_rng(1)
sessions = [srv.open_session(np.arange(i, i + 4), session_id=f"tenant{{i}}")
            for i in range({args.sessions})]
for k in range(4):
    for i, s in enumerate(sessions):
        s.push(rng.integers(-128, 128, {args.chunk}).astype(np.int32))
    srv.step()
    for s in sessions:
        s.pull()
# leave work in flight: one more push per tenant, never stepped
for s in sessions:
    s.push(rng.integers(-128, 128, {args.chunk}).astype(np.int32))
print("victim: killing self with queued chunks and no clean shutdown",
      flush=True)
os.kill(os.getpid(), signal.SIGKILL)
"""

env = dict(os.environ)
env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                     + os.pathsep + env.get("PYTHONPATH", ""))
res = subprocess.run([sys.executable, "-c", VICTIM], env=env,
                     capture_output=True, text=True)
print(res.stdout, end="")
assert res.returncode == -signal.SIGKILL, res.stderr
print(f"victim exited with {res.returncode} (SIGKILL); journal at {journal}")

# phase 2: recover in THIS process and finish the streams
from repro.compiler import compile_bank                     # noqa: E402
from repro.filters import (fir_bit_layers_batch,            # noqa: E402
                           spread_lowpass_qbank)
from repro.serving import BankSessionServer                 # noqa: E402

qbank = spread_lowpass_qbank(64, args.taps)
prog = compile_bank(qbank)
srv = BankSessionServer.recover(journal, prog)
print(f"recovered {len(srv.sessions)} sessions; "
      f"journal stats: {srv.journal.stats()}")

# replay the victim's RNG to know what it pushed, then stream more
rng = np.random.default_rng(1)
streams = [[] for _ in range(args.sessions)]
for _ in range(5):
    for i in range(args.sessions):
        streams[i].append(rng.integers(-128, 128, args.chunk)
                          .astype(np.int32))
outs = [[] for _ in range(args.sessions)]
sessions = [srv.sessions[f"tenant{i}"] for i in range(args.sessions)]
for i, s in enumerate(sessions):
    out = s.pull()          # whatever recovery regenerated
    if out.shape[1]:
        outs[i].append(out)
for k in range(3):          # keep serving after the crash
    for i, s in enumerate(sessions):
        chunk = rng.integers(-128, 128, args.chunk).astype(np.int32)
        streams[i].append(chunk)
        s.push(chunk)
    srv.step()
    for i, s in enumerate(sessions):
        out = s.pull()
        if out.shape[1]:
            outs[i].append(out)
srv.step()
for i, s in enumerate(sessions):
    out = s.pull()
    if out.shape[1]:
        outs[i].append(out)

# the victim delivered the first 4 chunks' worth of output before dying;
# everything AFTER that watermark must match the uninterrupted oracle
n_pre = 4 * args.chunk - (args.taps - 1)
for i in range(args.sessions):
    x = np.concatenate(streams[i])
    ref = fir_bit_layers_batch(x[None, :], qbank)[np.arange(i, i + 4), 0]
    got = np.concatenate(outs[i], axis=1)
    assert np.array_equal(got, ref[:, n_pre:n_pre + got.shape[1]]), \
        f"tenant{i} stream mismatch after recovery"
srv.close()
print(f"all {args.sessions} tenants bit-exact across the crash "
      f"({got.shape[1]} post-crash samples each) — no duplicates, no gaps")
