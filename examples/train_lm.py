"""End-to-end training driver: a ~100M-param LM trained for a few hundred
steps on synthetic structured data, with fault-tolerant checkpointing.

    PYTHONPATH=src python examples/train_lm.py --steps 50        # quick
    PYTHONPATH=src python examples/train_lm.py --steps 300 --size 100m
"""
import argparse

from repro.configs import get_config
from repro.data import DataConfig, TokenPipeline
from repro.distributed.fault import TrainLoop
from repro.nn import count_params, model_decls
from repro.training import OptHParams, TrainHParams

SIZES = {
    "10m": dict(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                head_dim=64, d_ff=768, vocab_size=4096),
    "25m": dict(n_layers=6, d_model=384, n_heads=6, n_kv_heads=2,
                head_dim=64, d_ff=1152, vocab_size=8192),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 head_dim=64, d_ff=2304, vocab_size=16384),
}

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=50)
ap.add_argument("--size", choices=list(SIZES), default="10m")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_example")
args = ap.parse_args()

cfg = get_config("qwen2.5-3b").reduced(**SIZES[args.size])
print(f"model: {count_params(model_decls(cfg))/1e6:.1f}M params")
pipe = TokenPipeline(DataConfig(cfg.vocab_size, args.batch, args.seq,
                                seed=0, kind="markov"))
hp = TrainHParams(opt=OptHParams(learning_rate=1e-3, warmup_steps=20,
                                 total_steps=args.steps))
loop = TrainLoop(cfg, hp, pipe, args.ckpt_dir, ckpt_every=25)
hist = loop.run(args.steps)
first, last = hist[0], hist[-1]
print(f"step {first['step']}: loss {first['loss']:.3f}  ->  "
      f"step {last['step']}: loss {last['loss']:.3f}")
print(f"checkpoints in {args.ckpt_dir}; stragglers flagged: "
      f"{loop.stragglers.slow_steps}")
