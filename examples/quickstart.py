"""Quickstart: the paper in 40 lines.

Design a 127-tap FIR filter, quantize to int16 the paper's way, count the
BLMAC additions, then apply it three ways — classical dot product, the
cycle-accurate FPGA machine simulator, and the Pallas TPU kernel — and
check all three agree bit-for-bit.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (classical_equivalent_adds, fir_blmac_additions,
                        po2_quantize)
from repro.core.machine import FirBlmacMachine
from repro.filters import design_bank, fir_direct
from repro.kernels import blmac_fir

# 1. design + quantize (§3.1-§3.2)
h = design_bank(127, [("bandpass", (0.2, 0.5))])[0]
q, k = po2_quantize(h, bits=16)
print(f"quantized 127-tap bandpass, scale 2^{k}, max|coeff|={np.abs(q).max()}")

# 2. the paper's cost metric (§3.3)
adds = fir_blmac_additions(q)
classical = classical_equivalent_adds(127)
print(f"BLMAC additions per output: {adds}  "
      f"(classical equivalent: {classical}, {classical/adds:.2f}x better)")

# 3. apply it three ways
x = np.random.default_rng(0).integers(-128, 128, 127 + 100)
y_classical = fir_direct(x, q)

machine = FirBlmacMachine()
machine.program(q)
res = machine.run(x)
print(f"machine: {res.mean_cycles:.0f} cycles/output "
      f"(@400 MHz: {400/res.mean_cycles:.2f} Msample/s)")

y_kernel = blmac_fir(jnp.asarray(x, jnp.int32), q)

assert np.array_equal(y_classical, res.outputs), "machine mismatch!"
assert np.array_equal(y_classical, np.asarray(y_kernel)), "kernel mismatch!"
print("classical == machine == Pallas kernel, bit-exact  OK")
