"""The paper's full FIR study in miniature (§3 + §4).

Sweeps a slice of the filter space, reports the Fig. 3/4 statistics, the
§4 machine cycle counts and Tab. 4 throughput model, and (if matplotlib
is available) saves the addition-count plot.

    PYTHONPATH=src python examples/fir_filtering.py [--n-div 40]
"""
import argparse

import numpy as np

from repro.core import (adds_per_coeff, adds_per_tap, csd_digits, code_count,
                        fir_blmac_additions_batch, po2_quantize_batch)
from repro.filters import sweep_bank, sweep_specs

ap = argparse.ArgumentParser()
ap.add_argument("--n-div", type=int, default=40)
args = ap.parse_args()

for taps in (55, 127, 255):
    bank = sweep_bank(taps, args.n_div, "hamming")
    q, _ = po2_quantize_batch(bank, 16)
    adds = fir_blmac_additions_batch(q)
    print(f"N={taps:3d}: {len(bank)} filters  "
          f"B_N={adds.mean():6.1f}±{adds.std():5.1f}  "
          f"adds/coeff={adds_per_coeff(adds, taps).mean():.2f}  "
          f"adds/tap={adds_per_tap(adds, taps).mean():.2f}")

# §4: machine cycle statistics + Tab. 4 throughput model for 127 taps
bank = sweep_bank(127, args.n_div, "hamming")
q, _ = po2_quantize_batch(bank, 16)
digits = csd_digits(q[:, :64], 16)
codes = np.count_nonzero(digits, axis=(1, 2)) + 16
fits = codes <= 256
print(f"\n127-tap machine: mean {codes.mean():.1f} cycles/output "
      f"(paper ~231.6); {100*(~fits).mean():.1f}% exceed the 256-code "
      f"weight memory (paper ~18%)")
for fam, mhz in [("Artix 7", 316.8), ("Kintex 7", 407.3),
                 ("Ultrascale+", 800.0)]:
    print(f"  {fam:12s} @{mhz:6.1f} MHz -> {mhz/codes.mean():.2f} Msample/s")
