"""Batched serving with BLMAC CSD-P quantized weights.

Loads (or initializes) a model, quantizes every linear weight to its P
most-significant CSD pulses — the paper's variable-precision dot product
as a deployment feature — and compares generations and weight-storage cost
against the bf16 baseline.

    PYTHONPATH=src python examples/serve_lm.py --planes 4
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.serve_quant import quantize_param_tree
from repro.nn import init_params, model_decls
from repro.serving import ServeEngine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2.5-3b")
ap.add_argument("--planes", type=int, default=4)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--new-tokens", type=int, default=12)
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
params = init_params(model_decls(cfg), jax.random.key(0))
prompts = np.random.default_rng(0).integers(
    0, cfg.vocab_size, (args.batch, 16)).astype(np.int32)

base = ServeEngine(cfg, params, cache_len=128)
t0 = time.time()
out_base = np.asarray(base.generate(prompts, args.new_tokens))
print(f"bf16 baseline: {time.time()-t0:.2f}s  tokens:\n{out_base[:2]}")

qparams, stats = quantize_param_tree(params, args.planes)
print(f"CSD-{args.planes}: {stats['n_quantized']} matrices quantized, "
      f"mean rel err {stats['mean_rel_err']:.4f}, "
      f"{stats['bits_per_weight']:.1f} bits/weight stored "
      f"({stats['bits_per_weight_achievable']:.1f} achievable) vs 16 bf16")
quant = ServeEngine(cfg, qparams, cache_len=128)
out_q = np.asarray(quant.generate(prompts, args.new_tokens))
agree = (out_base == out_q).mean()
print(f"greedy-token agreement vs bf16: {100*agree:.1f}%")
